"""Benchmark driver — one function per paper table.

Prints ``name,us_per_call,derived`` CSV per benchmark row, plus the
roofline table from the latest dry-run artifacts if present.

  PYTHONPATH=src python -m benchmarks.run [--rows N] [--quick]
"""
import argparse
import json
import sys


_HOTPATH_METRICS = ("diff_cold_s", "diff_warm_s", "merge_s")
_WORKFLOW_METRICS = ("branch_s", "pr_diff_s", "publish_s", "revert_s")


def _row_metrics(row_or_op):
    op = row_or_op if isinstance(row_or_op, str) else row_or_op["op"]
    return _WORKFLOW_METRICS if op.startswith("Workflow") else _HOTPATH_METRICS


def _fold_hotpath_trajectory(prev_path, n_rows, rows, note):
    """Fold a fresh hotpath/workflow run into the committed before/after
    shape.

    ``before`` comes from the previous BENCH json — its ``after`` block when
    it is itself a trajectory file, its raw metrics otherwise — so each PR's
    committed file always compares against the immediately preceding engine
    (ROADMAP: keep ``BENCH_vcs.json`` monotone). Rows the previous file
    lacks (a freshly added scenario) enter as raw metrics and seed the next
    PR's ``before``."""
    with open(prev_path) as f:
        prev = json.load(f)
    prev_by_key = {}
    for r in prev.get("results", []):
        op = r.get("op") or f"HotDiffMerge{r['mode']}"
        src = r.get("after", r)
        prev_by_key[(op, r["change"])] = {
            m: src[m] for m in _row_metrics(op) if m in src}
    results = []
    for r in rows:
        metrics = _row_metrics(r)
        before = prev_by_key.get((r["op"], r["change"]))
        after = {m: r[m] for m in metrics}
        entry = {"op": r["op"], "change": r["change"], "rows": r["rows"],
                 "changed_rows": r["changed_rows"]}
        if before:
            entry["before"] = before
            entry["after"] = after
            for m in metrics:
                if m in before and after[m] > 0:
                    entry[f"speedup_{m[:-2]}"] = round(before[m] / after[m], 2)
        else:
            entry.update(after)
        results.append(entry)
    out = {"bench": "diff_merge_hotpath", "rows": n_rows,
           "change_sets": {r["change"]: r["changed_rows"] for r in rows},
           "results": results}
    if note:
        out["note"] = note
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=None,
                    help="base table rows (default 2M; --quick = 200k)")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-collab", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as JSON (e.g. BENCH_vcs.json)")
    ap.add_argument("--hotpath-only", action="store_true",
                    help="run only the visibility hot-path benchmark")
    ap.add_argument("--compare-to", default=None, metavar="PATH",
                    help="previous hotpath BENCH json: fold the fresh run "
                         "into the before/after trajectory structure "
                         "(before = previous file's after/raw numbers)")
    ap.add_argument("--note", default=None,
                    help="free-form note stored in the --compare-to output")
    ap.add_argument("--repeat", type=int, default=1, metavar="N",
                    help="hotpath only: run N times and keep the per-case "
                         "minimum of each timing (robust against noisy "
                         "shared-tenancy machines)")
    args = ap.parse_args()
    n_rows = args.rows or (200_000 if args.quick else 2_000_000)

    from . import vcs_tables as V

    if args.hotpath_only:
        run_once = lambda: (V.diff_merge_hotpath(n_rows)
                            + V.workflow_scenario(n_rows))
        rows = run_once()
        for rep in range(args.repeat - 1):
            print(f"# repeat {rep + 2}/{args.repeat} (min-fold)")
            for r, r2 in zip(rows, run_once()):
                for m in _row_metrics(r) + ("diff_warm_avg_s",):
                    if m in r:
                        r[m] = min(r[m], r2[m])
        for r in rows:
            if r["op"].startswith("Workflow"):
                print(f"workflow/{r['op']}/{r['change']}: "
                      f"branch {r['branch_s']*1e3:.1f}ms "
                      f"diff {r['pr_diff_s']*1e3:.1f}ms "
                      f"publish {r['publish_s']*1e3:.1f}ms "
                      f"revert {r['revert_s']*1e3:.1f}ms")
                continue
            print(f"hotpath/{r['op']}/{r['change']}: "
                  f"diff cold {r['diff_cold_s']*1e3:.1f}ms "
                  f"warm {r['diff_warm_s']*1e3:.1f}ms "
                  f"merge {r['merge_s']*1e3:.1f}ms "
                  f"builds c/w/m={r['visibility_builds_cold']}"
                  f"/{r['visibility_builds_warm']}"
                  f"/{r['visibility_builds_merge']}")
        if args.json:
            payload = {"bench": "diff_merge_hotpath", "rows": n_rows,
                       "results": rows}
            if args.compare_to:
                payload = _fold_hotpath_trajectory(
                    args.compare_to, n_rows, rows, args.note)
            with open(args.json, "w") as f:
                json.dump(payload, f, indent=1)
        return

    json_out = {"rows": n_rows, "sections": {}}
    print("name,us_per_call,derived")

    # ---- Table 1: clone vs insert
    t1 = V.table1_clone(n_rows)
    json_out["sections"]["table1"] = t1
    for r in t1:
        print(f"table1/{r['op']},{r['time_s']*1e6:.0f},"
              f"space_bytes={r['space_bytes']}")
    sys.stdout.flush()

    # ---- Tables 2/3: diff + merge, builtin vs SQL
    t23 = V.table23_diff_merge(n_rows)
    json_out["sections"]["table23"] = t23
    for r in t23:
        kind = "table2" if r["op"].startswith("Diff") else "table3"
        print(f"{kind}/{r['op']}/{r['change']}/builtin,"
              f"{r['builtin_s']*1e6:.0f},speedup="
              f"{r['sql_s']/max(r['builtin_s'],1e-9):.1f}x")
        print(f"{kind}/{r['op']}/{r['change']}/sql,{r['sql_s']*1e6:.0f},")
    sys.stdout.flush()

    # ---- visibility hot path (ISSUE 1): cold vs warm diffs + counters
    hp = V.diff_merge_hotpath(n_rows)
    json_out["sections"]["hotpath"] = hp
    for r in hp:
        print(f"hotpath/{r['op']}/{r['change']}/diff_warm,"
              f"{r['diff_warm_s']*1e6:.0f},"
              f"cold_us={r['diff_cold_s']*1e6:.0f};"
              f"builds_warm={r['visibility_builds_warm']}")
    sys.stdout.flush()

    # ---- workflow porcelain (ISSUE 3): branch -> PR -> publish -> revert
    wf = V.workflow_scenario(n_rows)
    json_out["sections"]["workflow"] = wf
    for r in wf:
        print(f"workflow/{r['op']}/{r['change']}/publish,"
              f"{r['publish_s']*1e6:.0f},"
              f"branch_us={r['branch_s']*1e6:.0f};"
              f"diff_us={r['pr_diff_s']*1e6:.0f};"
              f"revert_us={r['revert_s']*1e6:.0f}")
    sys.stdout.flush()

    if not args.skip_collab:
        # ---- Tables 4/5: collaborative, no conflicts
        t45 = V.collaborative(n_rows, overlap=0.0)
        json_out["sections"]["table45"] = t45
        for r in t45:
            print(f"table45/{r['op']}/{r['change']}/diff,"
                  f"{r['diff_avg_s']*1e6:.0f},")
            print(f"table45/{r['op']}/{r['change']}/merge,"
                  f"{r['merge_avg_s']*1e6:.0f},"
                  f"timeline={'|'.join(str(t) for t in r['merge_times'])}")
        sys.stdout.flush()
        # ---- Tables 6/7: collaborative, 10% overlap conflicts
        t67 = V.collaborative(n_rows, overlap=0.10)
        json_out["sections"]["table67"] = t67
        for r in t67:
            print(f"table67/{r['op']}/{r['change']}/diff,"
                  f"{r['diff_avg_s']*1e6:.0f},conflicts={r['true_conflicts']}")
            print(f"table67/{r['op']}/{r['change']}/merge,"
                  f"{r['merge_avg_s']*1e6:.0f},"
                  f"timeline={'|'.join(str(t) for t in r['merge_times'])}")
        sys.stdout.flush()

    if args.json:
        with open(args.json, "w") as f:
            json.dump(json_out, f, indent=1)

    # ---- Roofline table (from dry-run artifacts, if present)
    from . import roofline
    print()
    roofline.render("dryrun_results.json")


if __name__ == '__main__':
    main()
