"""Benchmark driver — one function per paper table.

Prints ``name,us_per_call,derived`` CSV per benchmark row, plus the
roofline table from the latest dry-run artifacts if present.

  PYTHONPATH=src python -m benchmarks.run [--rows N] [--quick]
"""
import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=None,
                    help="base table rows (default 2M; --quick = 200k)")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-collab", action="store_true")
    args = ap.parse_args()
    n_rows = args.rows or (200_000 if args.quick else 2_000_000)

    from . import vcs_tables as V

    print("name,us_per_call,derived")

    # ---- Table 1: clone vs insert
    for r in V.table1_clone(n_rows):
        print(f"table1/{r['op']},{r['time_s']*1e6:.0f},"
              f"space_bytes={r['space_bytes']}")
    sys.stdout.flush()

    # ---- Tables 2/3: diff + merge, builtin vs SQL
    for r in V.table23_diff_merge(n_rows):
        kind = "table2" if r["op"].startswith("Diff") else "table3"
        print(f"{kind}/{r['op']}/{r['change']}/builtin,"
              f"{r['builtin_s']*1e6:.0f},speedup="
              f"{r['sql_s']/max(r['builtin_s'],1e-9):.1f}x")
        print(f"{kind}/{r['op']}/{r['change']}/sql,{r['sql_s']*1e6:.0f},")
    sys.stdout.flush()

    if not args.skip_collab:
        # ---- Tables 4/5: collaborative, no conflicts
        for r in V.collaborative(n_rows, overlap=0.0):
            print(f"table45/{r['op']}/{r['change']}/diff,"
                  f"{r['diff_avg_s']*1e6:.0f},")
            print(f"table45/{r['op']}/{r['change']}/merge,"
                  f"{r['merge_avg_s']*1e6:.0f},"
                  f"timeline={'|'.join(str(t) for t in r['merge_times'])}")
        sys.stdout.flush()
        # ---- Tables 6/7: collaborative, 10% overlap conflicts
        for r in V.collaborative(n_rows, overlap=0.10):
            print(f"table67/{r['op']}/{r['change']}/diff,"
                  f"{r['diff_avg_s']*1e6:.0f},conflicts={r['true_conflicts']}")
            print(f"table67/{r['op']}/{r['change']}/merge,"
                  f"{r['merge_avg_s']*1e6:.0f},"
                  f"timeline={'|'.join(str(t) for t in r['merge_times'])}")
        sys.stdout.flush()

    # ---- Roofline table (from dry-run artifacts, if present)
    from . import roofline
    print()
    roofline.render("dryrun_results.json")


if __name__ == '__main__':
    main()
