"""Benchmark driver — one function per paper table.

Prints ``name,us_per_call,derived`` CSV per benchmark row, plus the
roofline table from the latest dry-run artifacts if present.

  PYTHONPATH=src python -m benchmarks.run [--rows N] [--quick]
"""
import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=None,
                    help="base table rows (default 2M; --quick = 200k)")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-collab", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as JSON (e.g. BENCH_vcs.json)")
    ap.add_argument("--hotpath-only", action="store_true",
                    help="run only the visibility hot-path benchmark")
    args = ap.parse_args()
    n_rows = args.rows or (200_000 if args.quick else 2_000_000)

    from . import vcs_tables as V

    if args.hotpath_only:
        rows = V.diff_merge_hotpath(n_rows)
        for r in rows:
            print(f"hotpath/{r['op']}/{r['change']}: "
                  f"diff cold {r['diff_cold_s']*1e3:.1f}ms "
                  f"warm {r['diff_warm_s']*1e3:.1f}ms "
                  f"merge {r['merge_s']*1e3:.1f}ms "
                  f"builds c/w/m={r['visibility_builds_cold']}"
                  f"/{r['visibility_builds_warm']}"
                  f"/{r['visibility_builds_merge']}")
        if args.json:
            with open(args.json, "w") as f:
                json.dump({"bench": "diff_merge_hotpath", "rows": n_rows,
                           "results": rows}, f, indent=1)
        return

    json_out = {"rows": n_rows, "sections": {}}
    print("name,us_per_call,derived")

    # ---- Table 1: clone vs insert
    t1 = V.table1_clone(n_rows)
    json_out["sections"]["table1"] = t1
    for r in t1:
        print(f"table1/{r['op']},{r['time_s']*1e6:.0f},"
              f"space_bytes={r['space_bytes']}")
    sys.stdout.flush()

    # ---- Tables 2/3: diff + merge, builtin vs SQL
    t23 = V.table23_diff_merge(n_rows)
    json_out["sections"]["table23"] = t23
    for r in t23:
        kind = "table2" if r["op"].startswith("Diff") else "table3"
        print(f"{kind}/{r['op']}/{r['change']}/builtin,"
              f"{r['builtin_s']*1e6:.0f},speedup="
              f"{r['sql_s']/max(r['builtin_s'],1e-9):.1f}x")
        print(f"{kind}/{r['op']}/{r['change']}/sql,{r['sql_s']*1e6:.0f},")
    sys.stdout.flush()

    # ---- visibility hot path (ISSUE 1): cold vs warm diffs + counters
    hp = V.diff_merge_hotpath(n_rows)
    json_out["sections"]["hotpath"] = hp
    for r in hp:
        print(f"hotpath/{r['op']}/{r['change']}/diff_warm,"
              f"{r['diff_warm_s']*1e6:.0f},"
              f"cold_us={r['diff_cold_s']*1e6:.0f};"
              f"builds_warm={r['visibility_builds_warm']}")
    sys.stdout.flush()

    if not args.skip_collab:
        # ---- Tables 4/5: collaborative, no conflicts
        t45 = V.collaborative(n_rows, overlap=0.0)
        json_out["sections"]["table45"] = t45
        for r in t45:
            print(f"table45/{r['op']}/{r['change']}/diff,"
                  f"{r['diff_avg_s']*1e6:.0f},")
            print(f"table45/{r['op']}/{r['change']}/merge,"
                  f"{r['merge_avg_s']*1e6:.0f},"
                  f"timeline={'|'.join(str(t) for t in r['merge_times'])}")
        sys.stdout.flush()
        # ---- Tables 6/7: collaborative, 10% overlap conflicts
        t67 = V.collaborative(n_rows, overlap=0.10)
        json_out["sections"]["table67"] = t67
        for r in t67:
            print(f"table67/{r['op']}/{r['change']}/diff,"
                  f"{r['diff_avg_s']*1e6:.0f},conflicts={r['true_conflicts']}")
            print(f"table67/{r['op']}/{r['change']}/merge,"
                  f"{r['merge_avg_s']*1e6:.0f},"
                  f"timeline={'|'.join(str(t) for t in r['merge_times'])}")
        sys.stdout.flush()

    if args.json:
        with open(args.json, "w") as f:
            json.dump(json_out, f, indent=1)

    # ---- Roofline table (from dry-run artifacts, if present)
    from . import roofline
    print()
    roofline.render("dryrun_results.json")


if __name__ == '__main__':
    main()
