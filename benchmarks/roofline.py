"""§Roofline report: read dry-run artifacts and print the per-cell table."""
from __future__ import annotations

import json
import os
import sys

HDR = ("arch shape mesh chips bottleneck t_compute_s t_memory_s "
       "t_collective_s useful_ratio roofline_frac per_dev_GB").split()


def render(results_path: str = "dryrun_results.json", csv: bool = False):
    if not os.path.exists(results_path):
        print(f"(no {results_path} yet — run repro.launch.dryrun first)")
        return []
    rows = []
    for r in json.load(open(results_path)):
        if "error" in r:
            rows.append([r["arch"], r["shape"], r["mesh"], "-", "ERROR",
                         "-", "-", "-", "-", "-", "-"])
            continue
        rows.append([
            r["arch"], r["shape"], r["mesh"], r["chips"], r["bottleneck"],
            f"{r['t_compute_s']:.3g}", f"{r['t_memory_s']:.3g}",
            f"{r['t_collective_s']:.3g}",
            f"{r['useful_flops_ratio']:.3f}",
            f"{r['roofline_fraction']:.3f}",
            f"{r['per_device_bytes']/1e9:.2f}",
        ])
    sep = "," if csv else None
    w = [max(len(str(x)) for x in [h] + [row[i] for row in rows])
         for i, h in enumerate(HDR)]
    if csv:
        print(",".join(HDR))
        for row in rows:
            print(",".join(str(x) for x in row))
    else:
        print("  ".join(h.ljust(w[i]) for i, h in enumerate(HDR)))
        for row in rows:
            print("  ".join(str(x).ljust(w[i]) for i, x in enumerate(row)))
    return rows


if __name__ == "__main__":
    render(sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json",
           csv="--csv" in sys.argv)
