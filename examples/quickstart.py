"""Quickstart: the paper's git-for-data operations in 80 lines.

Runs the paper §3 workflow (Listing 1): snapshot → clone → independent
edits → diff → three-way merge, on a small lineitem-like table — then
shares the result with a second repo through a bare remote directory
(push → shallow clone → fetch → pull).

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.paper_vcs import LINEITEM_SCHEMA, gen_lineitem
from repro.core import (ConflictMode, Engine, snapshot_diff,
                        three_way_merge)

engine = Engine()
engine.create_table("lineitem", LINEITEM_SCHEMA)
engine.insert("lineitem", gen_lineitem(100_000))
print(f"lineitem: {engine.table('lineitem').count():,} rows")

# CREATE SNAPSHOT sn1 FOR TABLE lineitem        (a git tag)
sn1 = engine.create_snapshot("sn1", "lineitem")

# CREATE TABLE t FROM SNAPSHOT lineitem{sn1}    (instant clone)
engine.clone_table("t", "sn1")
print(f"clone cost: {engine.table('t').directory.meta_nbytes()} metadata "
      f"bytes (data shared, zero copy)")

# both branches evolve independently (values actually change!)
base = gen_lineitem(100_000)


def edited(sl, price_bump, tag):
    out = {k: v[sl].copy() for k, v in base.items()}
    out["l_extendedprice"] = out["l_extendedprice"] * price_bump
    out["l_comment"] = np.array(
        [b"%s-%d" % (tag, i) for i in range(len(out["l_comment"]))],
        dtype=object)
    return out


engine.update_by_keys("lineitem", edited(slice(0, 12), 1.10, b"repriced"))
tx = engine.begin()                           # branch: fix eight comments
tx.update_by_keys("t", edited(slice(40, 48), 1.0, b"fixed"))
tx.commit()
sn2 = engine.create_snapshot("sn2", "lineitem")
sn3 = engine.create_snapshot("sn3", "t")

# SNAPSHOT DIFF lineitem{sn2} AND t{sn3}
d = snapshot_diff(engine.store, sn2, sn3)
print(f"diff: {d.n_groups} differing value-groups; "
      f"scanned {d.stats.rows_scanned:,} rows "
      f"(vs {engine.table('lineitem').count():,} full scan)")

# SNAPSHOT MERGE TABLE lineitem FROM t{sn3} [BASED ON sn1] ACCEPT
rep = three_way_merge(engine, "lineitem", sn3, base=sn1,
                      mode=ConflictMode.ACCEPT)
print(f"merge: {rep.true_conflicts} true / {rep.false_conflicts} false "
      f"conflicts, +{rep.inserted}/-{rep.deleted} rows, "
      f"commit ts {rep.commit_ts}")

# verify: lineitem now contains t's comment fixes AND its own repricing
d2 = snapshot_diff(engine.store, engine.current_snapshot("lineitem"), sn3)
print(f"post-merge diff vs branch: {d2.n_groups} groups "
      f"(= main's own repricing, as expected)")

# ---------------------------------------------------------------- remotes
# Share the repo through a bare remote directory (ISSUE 10). A remote is
# just refs + WAL + content-addressed pack objects; push/pull move only
# the objects the other side lacks, and pulled objects carry their
# signatures — no row is ever re-hashed in transit.
import shutil
import tempfile

from repro.core.repo import Repo
from repro.store import clone
from repro.vcs_cli import load_repo

root = tempfile.mkdtemp(prefix="dg-quickstart-")
remote = f"{root}/origin"

repo = Repo(engine)
st = repo.push(remote)                        # PUSH TO 'dir'
print(f"push: {st['objects_pushed']} object(s), "
      f"{st['bytes_pushed']:,} bytes, {st['records_pushed']} WAL records")

# clone --shallow: refs now, objects fault in from origin on first scan
clone(remote, f"{root}/b.wal", shallow=True)
other = load_repo(f"{root}/b.wal")
print(f"shallow clone: {other.engine.table('lineitem').count():,} rows "
      f"visible before any object transfer")
other.fetch(remote)                           # optional bulk warm-up
st = other.pull(remote)                       # already current -> no-op
print(f"pull: up_to_date={st['up_to_date']}, "
      f"objects_pulled={st['objects_pulled']}")

# push is fast-forward-only: divergent histories are refused with a
# typed RemoteError telling you to pull first (try it!).
shutil.rmtree(root)
