"""Collaborative data-engineering workflow (paper §1, §6.3/§6.4) on the
ref-unified porcelain (ISSUE 5): one ref grammar, the ``Repo`` facade, and
the paper-style statement surface driving the same verbs.

Two engineers branch the production dataset, edit in isolation, open PRs,
and CI checks gate what lands. A failing check blocks one publish until the
data is fixed; a conflicting PR is reviewed and force-resolved; a bad
release is rolled back with an inverse-delta revert — history-preserving,
unlike the head-rewriting restore. Every version is named by a REF
(``snap:release-1``, ``lineitem@{ts}``, ``pr:2:merged``, ``lineitem~1``) —
no Python object handles required.

  PYTHONPATH=src python examples/data_engineering_workflow.py
"""
import numpy as np

from repro.configs.paper_vcs import LINEITEM_SCHEMA, gen_lineitem
from repro.core import (MergeConflictError, PublishBlocked, Repo, execute)

N_ROWS = 100_000
repo = Repo()
engine = repo.engine
repo.create_table("lineitem", LINEITEM_SCHEMA)
base = gen_lineitem(N_ROWS)
repo.insert("lineitem", base)
print(f"prod lineitem: {repo.table('lineitem').count():,} rows")

# -- branches: isolated metadata-only forks, created by STATEMENT --------
bytes_before = engine.store.bytes_written
print(execute(repo, "CREATE BRANCH relabel FOR (lineitem)").message)
print(execute(repo, "CREATE BRANCH cleanup FOR (lineitem)").message)
assert engine.store.bytes_written == bytes_before  # zero data copied
print(execute(repo, "SHOW BRANCHES").message)


def edit(sl, flag_shift, discount=None):
    out = {k: v[sl].copy() for k, v in base.items()}
    out["l_returnflag"] = (out["l_returnflag"] + flag_shift) % 3
    if discount is not None:
        out["l_discount"] = np.full_like(out["l_discount"], discount)
    out["l_comment"] = np.array(
        [b"edit-%d-%d" % (flag_shift, i) for i in range(len(out["l_comment"]))],
        dtype=object)
    return out


# -- engineer 1 relabels a shard — but fat-fingers an illegal discount --
repo.update_by_keys("relabel/lineitem", edit(slice(0, 2_000), 1,
                                             discount=0.75))
# -- engineer 2 cleans an overlapping shard ------------------------------
repo.update_by_keys("cleanup/lineitem", edit(slice(1_000, 3_000), 2))

# -- pull requests: pinned-base review diffs + CI checks -----------------
pr1 = repo.open_pr("relabel")            # INTO main (the default)
pr2 = repo.open_pr("cleanup")


def discount_rule(ctx):
    batch, _ = ctx.scan("lineitem")
    return bool((batch["l_discount"] <= 0.1).all())


def row_count_stable(ctx):
    return ctx.count("lineitem") == N_ROWS


for pr in (pr1, pr2):
    pr.add_check(discount_rule)
    pr.add_check(row_count_stable)
    # review diff by REF: the PR's pinned base against its head branch
    d = repo.diff(f"pr:{pr.id}:base", f"pr:{pr.id}:head", table="lineitem")
    print(f"PR#{pr.id} {pr.head_name}: {d.n_groups:5d} changed groups, "
          f"rows scanned {d.stats.rows_scanned:,}")

# -- publish #1: CI catches the bad discount and BLOCKS the publish ------
try:
    repo.publish(pr1.id)
except PublishBlocked as e:
    print(f"PR#{pr1.id} blocked: {e}")
# the engineer fixes the branch; the same PR then lands atomically
repo.update_by_keys("relabel/lineitem", edit(slice(0, 2_000), 1))
rep = repo.publish(pr1.id)["lineitem"]
print(f"PR#{pr1.id} published: +{rep.inserted}/-{rep.deleted} "
      f"at ts={pr1.publish_ts}")
print(execute(repo, "CREATE SNAPSHOT release-1 FOR TABLE lineitem").message)

# -- publish #2 conflicts (overlapping shard): review, then force --------
dry = pr2.dry_run_merge()["lineitem"]
print(f"PR#{pr2.id} dry run: {dry.true_conflicts} true conflicts "
      f"(no mutation)")
try:
    repo.publish(pr2.id)
except MergeConflictError as e:
    print(f"PR#{pr2.id}: {e.report.true_conflicts} true conflicts under "
          "FAIL -> reviewer ACCEPTs the cleanup branch's version")
rep = repo.publish(pr2.id, mode="theirs")["lineitem"]   # ACCEPT alias
print(f"PR#{pr2.id} published: +{rep.inserted}/-{rep.deleted} "
      f"at ts={pr2.publish_ts}")

# -- oops: release 2 broke the dashboard — revert it ---------------------
ts = repo.revert_pr(pr2.id)
d = repo.diff("HEAD", "snap:release-1", table="lineitem")
print(f"reverted PR#{pr2.id} at ts={ts} (Δ-sized, history-preserving): "
      f"{d.n_groups} diff groups vs snap:release-1 (0 = identical)")
# the reverted release stays reachable through PITR — by REF, not handle
d = repo.diff(f"pr:{pr2.id}:merged", "HEAD", table="lineitem")
print("published state still visible at its horizon:",
      d.n_groups, "groups differ")

# -- the commit log names every porcelain op that touched the table ------
print(execute(repo, "LOG TABLE lineitem LIMIT 6").message)

# -- housekeeping: close the done PRs, drop branches, GC ----------------
repo.close_pr(pr1.id)  # releases the published PR's revert pins
from repro.core.statements import execute_script
for res in execute_script(repo,
                          "DROP BRANCH relabel; DROP BRANCH cleanup; GC"):
    print(res.message)
