"""Collaborative data-engineering workflow (paper §1, §6.3/§6.4) on the
workflow porcelain: branch refs, data pull requests, CI-gated atomic
publish, and Δ-based revert.

Two engineers branch the production dataset, edit in isolation, open PRs,
and CI checks gate what lands. A failing check blocks one publish until the
data is fixed; a conflicting PR is reviewed and force-resolved; a bad
release is rolled back with an inverse-delta revert — history-preserving,
unlike the head-rewriting restore.

  PYTHONPATH=src python examples/data_engineering_workflow.py
"""
import numpy as np

from repro.configs.paper_vcs import LINEITEM_SCHEMA, gen_lineitem
from repro.core import (ConflictMode, Engine, MergeConflictError,
                        PublishBlocked, snapshot_diff)

N_ROWS = 100_000
rng = np.random.default_rng(7)
engine = Engine()
engine.create_table("lineitem", LINEITEM_SCHEMA)
base = gen_lineitem(N_ROWS)
engine.insert("lineitem", base)
print(f"prod lineitem: {engine.table('lineitem').count():,} rows")

# -- branches: isolated metadata-only forks of the production table -----
bytes_before = engine.store.bytes_written
engine.create_branch("relabel", ["lineitem"])
engine.create_branch("cleanup", ["lineitem"])
assert engine.store.bytes_written == bytes_before  # zero data copied
print("branches:", [b.name for b in engine.list_branches()],
      "(clones are metadata-only)")


def edit(sl, flag_shift, discount=None):
    out = {k: v[sl].copy() for k, v in base.items()}
    out["l_returnflag"] = (out["l_returnflag"] + flag_shift) % 3
    if discount is not None:
        out["l_discount"] = np.full_like(out["l_discount"], discount)
    out["l_comment"] = np.array(
        [b"edit-%d-%d" % (flag_shift, i) for i in range(len(out["l_comment"]))],
        dtype=object)
    return out


# -- engineer 1 relabels a shard — but fat-fingers an illegal discount --
engine.update_by_keys("relabel/lineitem", edit(slice(0, 2_000), 1,
                                               discount=0.75))
# -- engineer 2 cleans an overlapping shard ------------------------------
engine.update_by_keys("cleanup/lineitem", edit(slice(1_000, 3_000), 2))

# -- pull requests: pinned-base review diffs + CI checks -----------------
pr1 = engine.open_pr("main", "relabel")
pr2 = engine.open_pr("main", "cleanup")


def discount_rule(ctx):
    batch, _ = ctx.scan("lineitem")
    return bool((batch["l_discount"] <= 0.1).all())


def row_count_stable(ctx):
    return ctx.count("lineitem") == N_ROWS


for pr in (pr1, pr2):
    pr.add_check(discount_rule)
    pr.add_check(row_count_stable)
    d = pr.diff()["lineitem"]
    print(f"PR#{pr.id} {pr.head_name}: {d.n_groups:5d} changed groups, "
          f"rows scanned {d.stats.rows_scanned:,}")

# -- publish #1: CI catches the bad discount and BLOCKS the publish ------
try:
    pr1.publish()
except PublishBlocked as e:
    print(f"PR#{pr1.id} blocked: {e}")
# the engineer fixes the branch; the same PR then lands atomically
engine.update_by_keys("relabel/lineitem", edit(slice(0, 2_000), 1))
rep = pr1.publish()["lineitem"]
print(f"PR#{pr1.id} published: +{rep.inserted}/-{rep.deleted} "
      f"at ts={pr1.publish_ts}")

# -- publish #2 conflicts (overlapping shard): review, then force --------
dry = pr2.dry_run_merge()["lineitem"]
print(f"PR#{pr2.id} dry run: {dry.true_conflicts} true conflicts "
      f"(no mutation)")
try:
    pr2.publish()
except MergeConflictError as e:
    print(f"PR#{pr2.id}: {e.report.true_conflicts} true conflicts under "
          "FAIL -> reviewer ACCEPTs the cleanup branch's version")
rep = pr2.publish(mode=ConflictMode.ACCEPT)["lineitem"]
print(f"PR#{pr2.id} published: +{rep.inserted}/-{rep.deleted} "
      f"at ts={pr2.publish_ts}")

# -- oops: release 2 broke the dashboard — revert it ---------------------
ts = pr2.revert_publish()
cur = engine.current_snapshot("lineitem")
print(f"reverted PR#{pr2.id} at ts={ts} (Δ-sized, history-preserving): "
      f"{snapshot_diff(engine.store, cur, engine.snapshot_at('lineitem', pr1.publish_ts)).n_groups} "
      "diff groups vs release 1 (0 = identical)")
# the reverted release stays reachable through PITR — time travel intact
published = engine.snapshot_at("lineitem", pr2.publish_ts)
print("published state still visible at its horizon:",
      snapshot_diff(engine.store, published, cur).n_groups, "groups differ")

# -- housekeeping: close the done PRs, drop branches, GC ----------------
pr1.close()          # releases the published PR's revert pins
engine.drop_branch("relabel")
engine.drop_branch("cleanup")
stats = engine.gc()
print(f"gc: freed {stats.objects_freed} objects, pruned "
      f"{stats.versions_pruned} history versions, "
      f"{stats.pinned_horizons} pinned horizons honored")
