"""Collaborative data-engineering workflow (paper §6.3/§6.4 + §1).

Four engineers fork the dataset, label/clean their shard, open a
"pull request" (SNAPSHOT DIFF for review), CI validates it, and the change
is published to production in one atomic merge. One engineer's branch
conflicts with another's — resolved with ACCEPT after review.

  PYTHONPATH=src python examples/data_engineering_workflow.py
"""
import numpy as np

from repro.configs.paper_vcs import LINEITEM_SCHEMA, gen_lineitem
from repro.core import (ConflictMode, Engine, MergeConflictError,
                        snapshot_diff, three_way_merge)

rng = np.random.default_rng(7)
engine = Engine()
engine.create_table("prod", LINEITEM_SCHEMA)
engine.insert("prod", gen_lineitem(200_000))
print(f"prod: {engine.table('prod').count():,} rows")

release = engine.create_snapshot("release-1", "prod")

# -- each engineer forks from the release tag (instant, zero-copy) ------
workers = []
for w in range(4):
    t = engine.clone_table(f"eng{w}", "release-1")
    workers.append(t)

# -- independent edits: engineer w relabels their own row range ---------
base = gen_lineitem(200_000)


def relabel(sl, w):
    out = {k: v[sl].copy() for k, v in base.items()}
    out["l_returnflag"] = (out["l_returnflag"] + 1 + w) % 3  # new labels
    out["l_comment"] = np.array(
        [b"eng%d-%d" % (w, i) for i in range(len(out["l_comment"]))],
        dtype=object)
    return out


for w in range(4):
    lo = w * 12_000
    tx = engine.begin()
    tx.update_by_keys(f"eng{w}", relabel(slice(lo, lo + 2_000), w))
    # engineer 3 also touches engineer 0's range -> a true conflict later
    if w == 3:
        tx.update_by_keys(f"eng{w}", relabel(slice(100, 200), w))
    tx.commit()

# -- pull request: reviewer inspects SNAPSHOT DIFF vs the release -------
for w in range(4):
    snap = engine.create_snapshot(f"pr-{w}", f"eng{w}")
    d = snapshot_diff(engine.store, release, snap)
    payload = d.payload(engine.store)
    assert len(payload["l_orderkey"]) == d.n_groups
    # "CI": validate the changed rows satisfy business rules
    ok = bool((payload["l_quantity"] >= 0).all()
              and (payload["l_discount"] <= 0.1).all())
    print(f"PR-{w}: {d.n_groups:5d} changed groups, rows scanned "
          f"{d.stats.rows_scanned:,}, CI {'PASS' if ok else 'FAIL'}")

# -- publish: merge each PR into prod atomically ------------------------
for w in range(4):
    snap = engine.snapshots[f"pr-{w}"]
    try:
        rep = three_way_merge(engine, "prod", snap, mode=ConflictMode.FAIL)
    except MergeConflictError as e:
        print(f"merge PR-{w}: {e.report.true_conflicts} true conflicts "
              f"-> reviewer chose ACCEPT (take the PR's version)")
        rep = three_way_merge(engine, "prod", snap, mode=ConflictMode.ACCEPT)
    print(f"merge PR-{w}: +{rep.inserted}/-{rep.deleted} "
          f"(false={rep.false_conflicts} true={rep.true_conflicts}) "
          f"ts={rep.commit_ts}")

print(f"prod after merges: {engine.table('prod').count():,} rows")

# -- oops: bad deploy? instant rollback to the release tag --------------
engine.create_snapshot("release-2", "prod")
engine.restore_table("prod", "release-1")
print("rolled back to release-1:",
      snapshot_diff(engine.store, engine.current_snapshot("prod"),
                    release).n_groups, "diff groups (0 = identical)")
engine.restore_table("prod", "release-2")
print("rolled forward to release-2 — time travel both ways is metadata-only")
