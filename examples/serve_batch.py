"""Batched serving with continuous batching (reduced mixtral: MoE + SWA).

  PYTHONPATH=src python examples/serve_batch.py
"""
import numpy as np

from repro.launch.serve import Request, Server

srv = Server("mixtral-8x7b", reduced=True, batch=4, seq_cap=128,
             attn_block=16)
rng = np.random.default_rng(0)
reqs = [Request(i, rng.integers(2, srv.cfg.vocab,
                                size=int(rng.integers(8, 32))).astype(np.int32),
                max_new=24)
        for i in range(10)]
done, dt, steps = srv.run(reqs)
total = sum(len(r.out) for r in done)
print(f"served {len(done)} requests / {total} tokens in {dt:.1f}s "
      f"({steps} lockstep decode rounds, continuous batching)")
for r in done[:3]:
    print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.out[:8]}...")
