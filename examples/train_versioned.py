"""End-to-end training on versioned data with fault-tolerant checkpoints.

Demonstrates the full production loop on a reduced model:
  1. ingest a corpus into a versioned table, pin a snapshot, train;
  2. a fault is injected mid-run — the trainer detects the NaN state and
     rolls back to the last versioned checkpoint (instant metadata restore);
  3. a data engineer merges curated extra data into the corpus (the paper's
     branch-review-merge), a new snapshot is pinned, training continues —
     while the first run's pinned snapshot is untouched (isolation).

  PYTHONPATH=src python examples/train_versioned.py
"""
import numpy as np

from repro.core import ConflictMode, Engine, snapshot_diff, three_way_merge
from repro.data import add_samples, create_token_table, synth_corpus
from repro.launch.train import train_loop

# --- phase 1: train with an injected fault (rollback demo) -------------
state, losses, engine = train_loop(
    "qwen1.5-0.5b", steps=40, seq_len=64, global_batch=8,
    ckpt_every=10, inject_fault_at=25, log_every=10)
print(f"phase 1: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
      f"({len(losses)} healthy steps, incl. rollback recovery)")

# --- phase 2: curate more data on a branch and merge it ----------------
engine.clone_table("corpus_dev", engine.snapshots[
    [s for s in engine.snapshots if s.startswith("train-pin")][0]])
rng = np.random.default_rng(1)
new_ids = np.arange(1000, 1064)
add_samples(engine, "corpus_dev", new_ids,
            [rng.integers(2, 512, size=65).astype(np.uint32)
             for _ in new_ids])
dev_snap = engine.create_snapshot("curated", "corpus_dev")
d = snapshot_diff(engine.store,
                  engine.current_snapshot("corpus"), dev_snap)
print(f"phase 2: review diff = {d.n_groups} new/changed samples")
rep = three_way_merge(engine, "corpus", dev_snap, mode=ConflictMode.ACCEPT)
print(f"phase 2: merged {rep.inserted} curated samples into corpus "
      f"(atomic publish, ts={rep.commit_ts})")

# --- phase 3: continue training on the enriched corpus -----------------
state2, losses2, _ = train_loop(
    "qwen1.5-0.5b", steps=20, seq_len=64, global_batch=8,
    ckpt_every=10, engine=engine, log_every=10)
print(f"phase 3: loss {losses2[0]:.3f} -> {losses2[-1]:.3f} on merged data")
print("done: versioned data + versioned checkpoints, one engine.")
